//! Figure 8 — SM partition switching mechanisms under a repartition storm:
//! synchronous (global checkpoint), naive asynchronous, and Nexus's
//! buffered (hysteresis) asynchronous switching.
//!
//! Both streams run continuous work while a controller proposes a new
//! partition every iteration, oscillating ±3% around a drifting target with
//! occasional genuine shifts. We measure completed iterations, GPU
//! utilization, and the number of physical repartitions.
//!
//! `cargo bench --bench fig8_switching`

use nexus::gpusim::{GpuSpec, Sim};
use nexus::model::ModelConfig;
use nexus::util::fmt::Table;
use nexus::util::rng::Rng;

#[derive(Clone, Copy, PartialEq)]
enum Policy {
    Synchronous,
    NaiveAsync,
    Hysteresis(f64),
}

fn run(policy: Policy, horizon: f64) -> (usize, f64, usize) {
    let spec = GpuSpec::l20();
    let model = ModelConfig::qwen3b();
    let prefill = model.prefill_ops(512, 512.0 * 3000.0, 3000.0, 0);
    let decode = model.decode_ops(24, 24.0 * 1500.0);
    let mut sim = Sim::new(spec, 2);
    let mut rng = Rng::new(99);
    let mut applied_rp = 0.55f64;
    sim.set_partition(0, applied_rp);
    sim.set_partition(1, 1.0 - applied_rp);
    let mut completed = 0usize;
    let mut switches = 0usize;
    let mut tag = 0u64;
    let mut drift = 0.55f64;

    // Keep both streams fed; propose a repartition at each decode boundary.
    while sim.now() < horizon {
        for s in 0..2 {
            if !sim.busy(s) {
                tag += 1;
                sim.submit(s, if s == 0 { &prefill } else { &decode }, tag);
            }
        }
        let t = sim.peek_next_completion().unwrap();
        let done = sim.advance_to(t + 1e-12);
        completed += done.len();

        // Controller proposal: jitter ± occasional real shift.
        if rng.chance(0.02) {
            drift = rng.range_f64(0.35, 0.75);
        }
        let proposal = (drift + rng.range_f64(-0.03, 0.03)).clamp(0.1, 0.9);
        let apply = match policy {
            Policy::NaiveAsync => true,
            Policy::Hysteresis(delta) => (proposal - applied_rp).abs() >= delta,
            Policy::Synchronous => true,
        };
        if apply && (proposal - applied_rp).abs() > 1e-9 {
            if policy == Policy::Synchronous {
                // Global checkpoint: drain BOTH streams before switching —
                // the idle bubble of Fig. 8a.
                let drained = sim.drain();
                completed += drained.len();
            }
            applied_rp = proposal;
            sim.set_partition(0, applied_rp);
            sim.set_partition(1, 1.0 - applied_rp);
            switches += 1;
        }
    }
    let util = (sim.busy_time[0] + sim.busy_time[1]) / (2.0 * sim.now());
    (completed, util, switches)
}

fn main() {
    let horizon = 30.0;
    let mut t = Table::new(
        "Fig 8 — switching mechanism comparison (30s storm, proposal every iteration)",
        &["mechanism", "iterations done", "GPU utilization", "physical switches"],
    );
    for (name, policy) in [
        ("synchronous (drain both)", Policy::Synchronous),
        ("naive asynchronous", Policy::NaiveAsync),
        ("buffered async (δ=0.05)", Policy::Hysteresis(0.05)),
    ] {
        let (done, util, switches) = run(policy, horizon);
        t.row(&[
            name.to_string(),
            format!("{done}"),
            format!("{:.1}%", util * 100.0),
            format!("{switches}"),
        ]);
    }
    t.print();
    println!(
        "(expected: hysteresis ≈ naive-async throughput with ~10x fewer switches; \
         synchronous loses utilization to drain bubbles)"
    );
}
