//! Fleet bench — routing policies × engine kinds under bursty load, plus
//! cost-model-driven autoscaling vs. a static max-size fleet.
//!
//! Two questions the single-GPU figures cannot ask:
//!
//! 1. *Routing*: with per-replica queues building under Gamma-modulated
//!    bursts, load-aware dispatch (join-shortest-queue, least-KV-pressure)
//!    should hold tail TTFT far below state-oblivious round-robin at the
//!    highest rate point — long prompts pile onto unlucky replicas under RR.
//! 2. *Autoscaling*: the proactive autoscaler should track the diurnal
//!    envelope, spending fewer replica-seconds than a fleet statically
//!    provisioned for the peak, at comparable SLO attainment.
//!
//! Request count per point via `NEXUS_BENCH_N` (default 240).
//!
//! `cargo bench --bench fleet_scaling`

use nexus::cluster::{AutoscalerCfg, RoutingPolicy};
use nexus::coordinator::{ClusterExperiment, Experiment};
use nexus::engine::EngineKind;
use nexus::model::ModelConfig;
use nexus::util::fmt::{dur, Table};
use nexus::workload::{BurstyCfg, Dataset};

const REPLICAS: usize = 4;
const TTFT_SLO: f64 = 10.0;
const NORM_SLO: f64 = 0.30;

fn bench_n() -> usize {
    std::env::var("NEXUS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(240)
}

fn bursty(rate: f64) -> BurstyCfg {
    BurstyCfg {
        base_rate: rate,
        burst_shape: 0.4,
        epoch: 15.0,
        diurnal_amp: 0.6,
        diurnal_period: 240.0,
    }
}

fn fleet(kind: EngineKind, policy: RoutingPolicy, rate: f64, n: usize) -> ClusterExperiment {
    let base = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, n, rate);
    let mut exp = ClusterExperiment::new(base, REPLICAS, policy);
    exp.bursty = Some(bursty(rate));
    exp
}

fn main() {
    let n = bench_n();
    // Fleet-aggregate rates: ~2, ~4.5 and ~7 req/s per replica — the last
    // point runs each replica at/above its sustainable rate so queues form.
    let rates = [8.0, 18.0, 28.0];

    println!("=== routing policies x engines, {REPLICAS}-replica fleet, bursty ShareGPT ===");
    for &kind in &[EngineKind::Vllm, EngineKind::Sglang, EngineKind::Nexus] {
        let mut t = Table::new(
            &format!("{} x{} under bursty load ({} reqs/point)", kind.name(), REPLICAS, n),
            &["policy", "rate", "done", "TTFT", "TTFT95", "TBT95", "norm95", "SLO%"],
        );
        for &rate in &rates {
            for &policy in RoutingPolicy::all() {
                let m = fleet(kind, policy, rate, n).run(kind);
                let s = m.summary();
                t.row(&[
                    policy.name().to_string(),
                    format!("{rate:.0}"),
                    format!("{}", s.completed),
                    dur(s.mean_ttft),
                    dur(s.p95_ttft),
                    dur(s.p95_tbt),
                    dur(s.p95_norm),
                    format!("{:.1}", 100.0 * m.slo_attainment(TTFT_SLO, NORM_SLO)),
                ]);
            }
        }
        t.print();
    }
    println!(
        "(expected shape: at the highest rate, jsq and least-kv hold p95 TTFT well \
         below round-robin; affinity lands between)"
    );

    // --- Autoscaling: proactive fleet vs static peak provisioning. ---
    println!("\n=== autoscaler vs static max-size fleet (Nexus, bursty ShareGPT) ===");
    let rate = 18.0;
    let max_replicas = 6;
    let static_exp = {
        let base = Experiment::new(ModelConfig::qwen3b(), Dataset::ShareGpt, n, rate);
        let mut e = ClusterExperiment::new(base, max_replicas, RoutingPolicy::JoinShortestQueue);
        e.bursty = Some(bursty(rate));
        e
    };
    let auto_exp = {
        let mut e = static_exp.clone();
        e.replicas = 1;
        e.autoscale = Some(AutoscalerCfg {
            min_replicas: 1,
            max_replicas,
            interval: 5.0,
            cooldown: 15.0,
            ..AutoscalerCfg::default()
        });
        e
    };
    let st = static_exp.run(EngineKind::Nexus);
    let au = auto_exp.run(EngineKind::Nexus);
    let mut t = Table::new(
        &format!("static x{max_replicas} vs autoscaled [1..{max_replicas}]"),
        &["fleet", "done", "TTFT95", "norm95", "SLO%", "replica-s", "peak", "scales"],
    );
    for (name, m) in [("static-max", &st), ("autoscaled", &au)] {
        let s = m.summary();
        t.row(&[
            name.to_string(),
            format!("{}", s.completed),
            dur(s.p95_ttft),
            dur(s.p95_norm),
            format!("{:.1}", 100.0 * m.slo_attainment(TTFT_SLO, NORM_SLO)),
            format!("{:.0}", m.replica_seconds),
            format!("{}", m.peak_replicas),
            format!("{}", m.scale_events.len()),
        ]);
    }
    t.print();
    let saved = 100.0 * (1.0 - au.replica_seconds / st.replica_seconds.max(1e-9));
    println!(
        "autoscaler replica-seconds saving vs static peak: {saved:.1}% \
         (SLO attainment {:.1}% vs {:.1}%)",
        100.0 * au.slo_attainment(TTFT_SLO, NORM_SLO),
        100.0 * st.slo_attainment(TTFT_SLO, NORM_SLO),
    );
    for e in &au.scale_events {
        println!("  scale @ {:>7.1}s: {} -> {}", e.time, e.from, e.to);
    }
    println!(
        "(expected shape: autoscaled fleet uses materially fewer replica-seconds at \
         near-equal SLO attainment, tracking the diurnal envelope)"
    );
}
