//! Figure 10 — multi-GPU end-to-end: Qwen2.5-14B, TP=2, Mixed workload.
//! All systems use two L20s (vLLM/SGLang/Nexus via tensor parallelism,
//! vLLM-P/D as one prefill + one decode engine).
//!
//! `cargo bench --bench fig10_multi_gpu`

use nexus::coordinator::{sustainable_throughput, Experiment, SloSpec};
use nexus::engine::EngineKind;
use nexus::model::ModelConfig;
use nexus::util::fmt::{dur, Table};
use nexus::workload::Dataset;

fn main() {
    let n = std::env::var("NEXUS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(120);
    let model = ModelConfig::qwen14b().with_tp(2);
    // FastServe is excluded as in the paper (§6.2.2).
    let kinds = [EngineKind::Vllm, EngineKind::Sglang, EngineKind::VllmPD, EngineKind::Nexus];

    let mut t = Table::new(
        &format!("Fig 10 — Mixed / {} (TP=2, two L20s; {} reqs/point)", model.name, n),
        &["engine", "rate", "norm", "norm95", "TTFT", "TTFT95", "TBT", "TBT95", "gpus"],
    );
    for &kind in &kinds {
        // vLLM-P/D splits the two GPUs into one prefill + one decode engine
        // (TP=1 each) instead of sharding the model.
        let m = if kind == EngineKind::VllmPD { ModelConfig::qwen14b() } else { model };
        for rate in [1.5, 2.5, 3.5] {
            let exp = Experiment::new(m, Dataset::Mixed, n, rate);
            let s = exp.run(kind).summary();
            t.row(&[
                kind.name().to_string(),
                format!("{rate:.1}"),
                dur(s.mean_norm),
                dur(s.p95_norm),
                dur(s.mean_ttft),
                dur(s.p95_ttft),
                dur(s.mean_tbt),
                dur(s.p95_tbt),
                format!("{}", kind.gpus(&m)),
            ]);
        }
    }
    t.print();

    let mut t2 = Table::new(
        "max sustainable throughput (p95 norm ≤ 0.2 s/token)",
        &["engine", "req/s", "vs vLLM"],
    );
    let slo = SloSpec::default();
    let hi = 16.0;
    let mut vllm_thr = 0.0;
    for &kind in &kinds {
        let m = if kind == EngineKind::VllmPD { ModelConfig::qwen14b() } else { model };
        let base = Experiment::new(m, Dataset::Mixed, n.min(80), 1.0);
        let thr = sustainable_throughput(kind, &base, slo, 0.25, hi, 0.5);
        if kind == EngineKind::Vllm {
            vllm_thr = thr;
        }
        t2.row(&[
            kind.name().to_string(),
            if thr >= hi { format!("≥{hi:.0}") } else { format!("{thr:.2}") },
            if vllm_thr > 0.0 { format!("{:.2}x", thr / vllm_thr) } else { "—".into() },
        ]);
    }
    t2.print();
    println!(
        "(paper shape: Nexus 2.2x vLLM / 2x SGLang throughput; vLLM-P/D collapses — \
         aggressive prefill overruns the transfer buffer, forcing recomputation)"
    );
}
