//! Design-choice ablations beyond Fig. 13: the two tunable knobs the paper
//! discusses but does not sweep.
//!
//! (a) hysteresis buffer δ (§4.2): 0 → naive async (oscillation), large →
//!     unresponsive. Measures latency + applied repartitions.
//! (b) SPF age-decay γ (§4.3.1 / Eq. 10): 0 → pure SPF (starves long
//!     prompts, best mean TTFT), large → FCFS-like (fair, worse mean).
//!
//! `cargo bench --bench ablation_params`

use nexus::engine::{run_engine, EngineCfg, EngineKind};
use nexus::model::ModelConfig;
use nexus::util::fmt::{dur, Table};
use nexus::workload::{generate, Dataset};

fn main() {
    let n = std::env::var("NEXUS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100);
    let trace = generate(Dataset::Mixed, n, 3.0, 42);

    // (a) δ sweep.
    let mut t = Table::new(
        "hysteresis buffer δ (Mixed / llama8b @ 3 req/s)",
        &["delta", "TTFT", "TBT", "norm", "repartitions", "suppressed"],
    );
    for delta in [0.0, 0.02, 0.05, 0.10, 0.20] {
        let mut cfg = EngineCfg::new(ModelConfig::llama8b(), 42);
        cfg.partition.delta = delta;
        let m = run_engine(EngineKind::Nexus, &cfg, &trace);
        let s = m.summary();
        t.row(&[
            format!("{delta:.2}"),
            dur(s.mean_ttft),
            dur(s.mean_tbt),
            dur(s.mean_norm),
            format!("{}", m.repartitions),
            format!("{}", m.suppressed_repartitions),
        ]);
    }
    t.print();
    println!("(paper §4.2: δ filters transient noise; δ=0 degenerates to naive async)\n");

    // (b) γ sweep.
    let mut t = Table::new(
        "SPF age-decay γ (anti-starvation, Eq. 10)",
        &["gamma", "mean TTFT", "p95 TTFT", "p99-ish (max)", "mean norm"],
    );
    for gamma in [0.0, 5.0, 15.0, 50.0, 200.0] {
        let mut cfg = EngineCfg::new(ModelConfig::llama8b(), 42);
        cfg.gamma = gamma;
        let m = run_engine(EngineKind::Nexus, &cfg, &trace);
        let s = m.summary();
        let max_ttft = m
            .records
            .iter()
            .map(|r| r.ttft())
            .fold(0.0f64, f64::max);
        t.row(&[
            format!("{gamma:.0}"),
            dur(s.mean_ttft),
            dur(s.p95_ttft),
            dur(max_ttft),
            dur(s.mean_norm),
        ]);
    }
    t.print();
    println!(
        "(paper §4.3.1: low γ favors responsiveness (mean), high γ fairness (tail); \
         the default 15 balances them)"
    );
}
