//! Figure 5 — diminishing returns in prefill and decode with increasing SM
//! allocation: (a) end-to-end iteration latency normalized to the 10% SM
//! point, (b) prefill per-kernel breakdown, (c) decode per-kernel breakdown.
//!
//! `cargo bench --bench fig5_diminishing_returns`

use nexus::gpusim::{iteration_time_isolated, GpuSpec};
use nexus::model::{ModelConfig, OpClass, OpWork};
use nexus::util::fmt::Table;

fn main() {
    let spec = GpuSpec::l20();
    let model = ModelConfig::qwen3b();
    // Pure batches as in §3.2: a 512-token chunk over a 4k context, and a
    // 32-request decode batch with 1.5k contexts.
    let prefill = model.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
    let decode = model.decode_ops(32, 32.0 * 1500.0);
    let grid: Vec<f64> = (1..=10).map(|i| i as f64 / 10.0).collect();

    // (a) end-to-end, normalized to r=0.1.
    let mut t = Table::new(
        "Fig 5a — normalized iteration latency vs SM allocation",
        &["SM %", "prefill", "decode", "prefill Δ/10%", "decode Δ/10%"],
    );
    let base_p = iteration_time_isolated(&spec, &prefill, grid[0]);
    let base_d = iteration_time_isolated(&spec, &decode, grid[0]);
    let mut prev: Option<(f64, f64)> = None;
    for &r in &grid {
        let tp = iteration_time_isolated(&spec, &prefill, r);
        let td = iteration_time_isolated(&spec, &decode, r);
        let (dp, dd) = prev
            .map(|(pp, pd)| {
                (format!("-{:.0}%", 100.0 * (pp - tp) / pp), format!("-{:.0}%", 100.0 * (pd - td) / pd))
            })
            .unwrap_or_default();
        t.row(&[
            format!("{:.0}", r * 100.0),
            format!("{:.3}", tp / base_p),
            format!("{:.3}", td / base_d),
            dp,
            dd,
        ]);
        prev = Some((tp, td));
    }
    t.print();
    println!("(paper: prefill 30→40% cuts >25%, 70→80% cuts ~10%; decode <3% past 50%)\n");

    // (b)+(c) per-kernel breakdowns.
    for (name, ops, classes) in [
        (
            "Fig 5b — prefill kernel latency vs SMs (normalized to 10%)",
            &prefill,
            vec![OpClass::Qkv, OpClass::AttnPrefill, OpClass::AttnLinear, OpClass::Ffn],
        ),
        (
            "Fig 5c — decode kernel latency vs SMs (normalized to 10%)",
            &decode,
            vec![OpClass::Qkv, OpClass::AttnDecode, OpClass::AttnLinear, OpClass::Ffn],
        ),
    ] {
        let mut hdr: Vec<String> = vec!["SM %".into()];
        hdr.extend(classes.iter().map(|c| c.name().to_string()));
        let hdr_refs: Vec<&str> = hdr.iter().map(String::as_str).collect();
        let mut t = Table::new(name, &hdr_refs);
        let base: Vec<f64> = classes
            .iter()
            .map(|&c| kernel_time(&spec, ops, c, grid[0]))
            .collect();
        for &r in &grid {
            let mut row = vec![format!("{:.0}", r * 100.0)];
            for (i, &c) in classes.iter().enumerate() {
                row.push(format!("{:.3}", kernel_time(&spec, ops, c, r) / base[i]));
            }
            t.row(&row);
        }
        t.print();
        println!();
    }
    println!("(paper: FFN benefits most from SMs; decode attention saturates earliest)");
}

fn kernel_time(spec: &GpuSpec, ops: &[OpWork], class: OpClass, r: f64) -> f64 {
    let op: Vec<OpWork> = ops.iter().filter(|o| o.class == class).copied().collect();
    iteration_time_isolated(spec, &op, r)
}
