//! Figure 6 — memory contention's impact and variability.
//!
//! (a) decode latency vs the co-running prefill's KV length under a fixed
//!     50/50 SM partition (ground truth = the fluid simulator's
//!     demand-proportional bandwidth sharing), alongside the Eq. 8–9 cost
//!     model's prediction;
//! (b) prefill KV length over time in a replayed chunked-prefill run —
//!     the §3.3 variability that makes static partitioning insufficient.
//!
//! `cargo bench --bench fig6_mem_contention`

use nexus::costmodel::calibrate;
use nexus::gpusim::{GpuSpec, Sim};
use nexus::model::ModelConfig;
use nexus::util::fmt::{dur, Table};
use nexus::util::rng::Rng;
use nexus::util::{mean, percentile};
use nexus::workload::Dataset;

fn main() {
    let spec = GpuSpec::l20();
    let model = ModelConfig::qwen3b();
    let cost = calibrate(&spec);
    let decode = model.decode_ops(16, 16.0 * 2000.0);

    // (a) co-run a decode iteration with prefill chunks of growing KV.
    let mut t = Table::new(
        "Fig 6a — decode latency vs co-running prefill KV length (50/50 SMs)",
        &["prefill KV", "decode (sim)", "Δ vs 2k", "decode (cost model)", "decode (no prefill)"],
    );
    let solo = {
        let mut sim = Sim::new(spec, 2);
        sim.set_partition(0, 0.5);
        sim.set_partition(1, 0.5);
        sim.submit(1, &decode, 2);
        sim.drain().last().unwrap().time
    };
    let mut base = None;
    for kv_len in [2000.0, 4000.0, 6000.0, 8000.0, 10000.0] {
        let prefill = model.prefill_ops(512, 512.0 * kv_len, kv_len, 0);
        // Simulator ground truth: keep the prefill stream busy with
        // back-to-back chunks while one decode iteration runs.
        let mut sim = Sim::new(spec, 2);
        sim.set_partition(0, 0.5);
        sim.set_partition(1, 0.5);
        for k in 0..8 {
            sim.submit(0, &prefill, 100 + k);
        }
        sim.submit(1, &decode, 2);
        let done = sim.drain();
        let t_dec = done.iter().find(|c| c.tag == 2).unwrap().time;
        let b = *base.get_or_insert(t_dec);
        // Analytical prediction (Eq. 8–9 with rate-based shares).
        let pp = cost.prefill(&prefill, 0.5).pressure;
        let pred = cost.decode(&decode, 0.5, Some(&pp));
        t.row(&[
            format!("{kv_len:.0}"),
            dur(t_dec),
            format!("+{:.1}%", 100.0 * (t_dec - b) / b),
            dur(pred),
            dur(solo),
        ]);
    }
    t.print();
    println!(
        "(paper: +36% from 2k→10k on real hardware; the fluid average-rate model \
         reproduces the monotone shape at smaller magnitude — see EXPERIMENTS.md)\n"
    );

    // (b) prefill KV length variability in a replayed chunked run.
    let mut rng = Rng::new(7);
    let mut kv_series: Vec<f64> = Vec::new();
    // Replay: requests arrive, are prefilled in 512-token chunks FCFS; the
    // "prefill KV length" each iteration is the attended context of the
    // current chunk.
    let mut backlog: Vec<(usize, usize)> = Vec::new(); // (prompt, prefilled)
    for step in 0..4000 {
        if step % 3 == 0 {
            let (p, _) = Dataset::LongData.sample(&mut rng);
            backlog.push((p, 0));
        }
        if let Some(head) = backlog.first_mut() {
            let take = (head.0 - head.1).min(512);
            head.1 += take;
            kv_series.push(head.1 as f64);
            if head.1 >= head.0 {
                backlog.remove(0);
            }
        }
    }
    let windows: Vec<f64> = kv_series.chunks(50).map(mean).collect();
    let mut t = Table::new(
        "Fig 6b — prefill KV length variability over the run",
        &["stat", "tokens"],
    );
    t.row(&["mean".into(), format!("{:.0}", mean(&kv_series))]);
    t.row(&["p5".into(), format!("{:.0}", percentile(&kv_series, 5.0))]);
    t.row(&["p50".into(), format!("{:.0}", percentile(&kv_series, 50.0))]);
    t.row(&["p95".into(), format!("{:.0}", percentile(&kv_series, 95.0))]);
    let wmin = windows.iter().cloned().fold(f64::INFINITY, f64::min);
    let wmax = windows.iter().cloned().fold(0.0, f64::max);
    t.row(&["50-iter window min/max".into(), format!("{wmin:.0} / {wmax:.0}")]);
    t.print();
    println!("(fluctuates by >4x across windows → contention is not statically predictable)");
}
