//! Figure 9 — end-to-end single-GPU evaluation: three workloads × five
//! systems × a request-rate sweep; mean and P95 of normalized latency,
//! TTFT, and TBT (the paper's six columns), plus sustainable throughput.
//!
//! All systems use one simulated L20 except vLLM-P/D (two). Request count
//! per point is controlled by `NEXUS_BENCH_N` (default 120).
//!
//! `cargo bench --bench fig9_single_gpu`

use nexus::coordinator::{sustainable_throughput, Experiment, SloSpec};
use nexus::engine::EngineKind;
use nexus::model::ModelConfig;
use nexus::util::fmt::{dur, Table};
use nexus::workload::Dataset;

fn bench_n() -> usize {
    std::env::var("NEXUS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(120)
}

fn main() {
    let n = bench_n();
    let configs = [
        (Dataset::LongData, ModelConfig::qwen3b(), vec![1.0, 2.0, 3.0]),
        (Dataset::Arxiv, ModelConfig::qwen3b(), vec![1.5, 3.0, 4.5]),
        (Dataset::Mixed, ModelConfig::llama8b(), vec![1.5, 2.5, 3.5]),
    ];
    for (dataset, model, rates) in configs {
        println!("=== {} on {} ({} requests/point) ===", dataset.name(), model.name, n);
        let mut t = Table::new(
            &format!("Fig 9 — {} / {}", dataset.name(), model.name),
            &[
                "engine", "rate", "norm", "norm95", "TTFT", "TTFT95", "TBT", "TBT95",
            ],
        );
        for &kind in EngineKind::all() {
            for &rate in &rates {
                let exp = Experiment::new(model, dataset, n, rate);
                let s = exp.run(kind).summary();
                t.row(&[
                    kind.name().to_string(),
                    format!("{rate:.1}"),
                    dur(s.mean_norm),
                    dur(s.p95_norm),
                    dur(s.mean_ttft),
                    dur(s.p95_ttft),
                    dur(s.mean_tbt),
                    dur(s.p95_tbt),
                ]);
            }
        }
        t.print();

        // Columns 1–2 summary: max sustainable rate under the latency SLO.
        let mut t2 = Table::new(
            "max sustainable throughput (p95 norm ≤ 0.2 s/token)",
            &["engine", "req/s", "vs vLLM"],
        );
        let slo = SloSpec::default();
        let base = Experiment::new(model, dataset, n.min(80), 1.0);
        let hi = 16.0;
        let mut vllm_thr = 0.0;
        for &kind in EngineKind::all() {
            let thr = sustainable_throughput(kind, &base, slo, 0.25, hi, 0.5);
            if kind == EngineKind::Vllm {
                vllm_thr = thr;
            }
            t2.row(&[
                kind.name().to_string(),
                if thr >= hi { format!("≥{hi:.0}") } else { format!("{thr:.2}") },
                if vllm_thr > 0.0 { format!("{:.2}x", thr / vllm_thr) } else { "—".into() },
            ]);
        }
        t2.print();
        println!();
    }
    println!(
        "(paper shape: Nexus 1.5–2.2x vLLM throughput, 2–20x TTFT, 1.2–2.5x TBT; \
         SGLang between; FastServe good mean-TTFT / bad tail; vLLM-P/D best TBT on 2 GPUs)"
    );
}
