//! Table 1 — workload characteristics: generated length statistics next to
//! the paper's published rows.
//!
//! `cargo bench --bench table1_workloads`

use nexus::util::fmt::Table;
use nexus::workload::{generate, length_stats, table1_reference, Dataset};

fn main() {
    let n = std::env::var("NEXUS_BENCH_N")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8000usize);
    let reference = table1_reference();
    let mut t = Table::new(
        "Table 1 — workload length statistics (ours vs paper)",
        &["dataset", "dir", "mean", "P50", "P95", "P99", "paper mean/P50/P95/P99"],
    );
    for ds in [Dataset::LongData, Dataset::Arxiv, Dataset::ShareGpt] {
        let trace = generate(ds, n, 1.0, 123);
        let want = reference[ds.name()];
        let ins: Vec<usize> = trace.iter().map(|r| r.plen()).collect();
        let outs: Vec<usize> = trace.iter().map(|r| r.olen()).collect();
        for (dir, lens, w) in [("In", &ins, &want[0..4]), ("Out", &outs, &want[4..8])] {
            let (m, p50, p95, p99) = length_stats(lens);
            t.row(&[
                ds.name().to_string(),
                dir.to_string(),
                format!("{m:.0}"),
                format!("{p50:.0}"),
                format!("{p95:.0}"),
                format!("{p99:.0}"),
                format!("{:.0} / {:.0} / {:.0} / {:.0}", w[0], w[1], w[2], w[3]),
            ]);
        }
    }
    t.print();
    println!("({n} samples per dataset; fit = clamped log-normal on P50/P95)");
}
