//! Figure 11 — offline inference makespan: all requests submitted at t=0.
//! Long Data Collections on Qwen2.5-3B and Mixed on Llama3.1-8B; X marks a
//! timeout (FastServe's recompute collapse in the paper).
//!
//! `cargo bench --bench fig11_offline`

use nexus::coordinator::{offline_makespan, Experiment};
use nexus::engine::EngineKind;
use nexus::model::ModelConfig;
use nexus::util::fmt::{dur, Table};
use nexus::workload::Dataset;

fn main() {
    let n = std::env::var("NEXUS_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100);
    for (dataset, model) in [
        (Dataset::LongData, ModelConfig::qwen3b()),
        (Dataset::Mixed, ModelConfig::llama8b()),
    ] {
        let mut exp = Experiment::new(model, dataset, n, 1.0);
        // Offline batches stress memory: emulate the paper's tighter
        // effective KV budget under full batches.
        exp.seed = 42;
        let mut t = Table::new(
            &format!("Fig 11 — offline makespan: {} / {} ({} reqs)", dataset.name(), model.name, n),
            &["engine", "makespan", "tok/s", "vs vLLM", "gpus"],
        );
        let mut vllm_mk = None;
        for &kind in EngineKind::all() {
            match offline_makespan(kind, &exp) {
                Some((mk, m)) => {
                    if kind == EngineKind::Vllm {
                        vllm_mk = Some(mk);
                    }
                    t.row(&[
                        kind.name().to_string(),
                        dur(mk),
                        format!("{:.0}", m.summary().token_throughput),
                        vllm_mk
                            .map(|v| format!("{:+.0}%", 100.0 * (mk - v) / v))
                            .unwrap_or_default(),
                        format!("{}", kind.gpus(&exp.model)),
                    ]);
                }
                None => t.row(&[
                    kind.name().to_string(),
                    "X (timeout)".into(),
                    String::new(),
                    String::new(),
                    format!("{}", kind.gpus(&exp.model)),
                ]),
            }
        }
        t.print();
        println!();
    }
    println!(
        "(paper shape: Nexus 5–50% below vLLM on LDC; vLLM-P/D lowest but on 2 GPUs; \
         FastServe times out)"
    );
}
