//! §Perf — hot-path benchmarks for the L3 coordinator. These anchor the
//! ROADMAP §Perf iteration log: the partition decision must be ≪ 1 ms (it
//! runs per batch inside the serving loop), the simulator event loop bounds
//! experiment turnaround, and the schedulers must stay negligible (Fig. 12's
//! "scheduling overhead" row).
//!
//! Besides the microbenchmarks, this harness runs two fleet-scale
//! macro-benchmarks:
//!
//! * the PR-2 event-queue comparison — the cluster co-simulation at 16 and
//!   64 replicas on a bursty ShareGPT trace, timed under both the optimized
//!   O(log R) heap loop ([`Cluster::run`]) and the retained pre-refactor
//!   O(R)-scan loop ([`Cluster::run_reference`]), with a ≤ 1 ns
//!   structural-deviation check proving both loops served identically; and
//! * the sharded-loop scaling sweep — 64/256/1024 replicas ×
//!   {1, 4, 8} worker threads through [`Cluster::run_parallel`], digest-
//!   checked against the one-thread run (and against the sequential loop
//!   for the materialized rows). The 1024-replica row feeds arrivals
//!   through the streaming generator (`generate_bursty_iter` →
//!   `run_parallel_stream`) so the trace is never materialized; and
//! * the fleet prefix-cache sweep (schema v3, `prefix[]` rows) — chat-heavy
//!   multi-turn vs single-turn traffic × {affinity, JSQ, prefix-aware ×
//!   tier on/off}, carrying the PR-10 TTFT headline and the cold-path
//!   digest check (prefix-aware on untagged traffic must serve exactly
//!   as JSQ).
//!
//! Results are emitted machine-readably to `BENCH_hotpath.json` at the repo
//! root (schema documented in ROADMAP §Perf; regenerate with
//! `make bench-json`).
//!
//! `cargo bench --bench perf_hotpath`

use nexus::cluster::{plan_rebalance, Cluster, ClusterCfg, ParallelCfg, RoutingPolicy, StealCfg};
use nexus::coordinator::Experiment;
use nexus::costmodel::calibrate;
use nexus::engine::{EngineCfg, EngineKind};
use nexus::gpusim::{GpuSpec, Sim};
use nexus::model::ModelConfig;
use nexus::partition::{BatchState, PartitionConfig, PartitionController};
use nexus::sched::{spf_batch, PrefillItem};
use nexus::util::fmt::Table;
use nexus::util::json::Json;
use nexus::util::rng::Rng;
use std::time::Instant;

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn micro_row(name: &str, seconds_per_op: f64) -> Json {
    Json::obj(vec![
        ("name", name.into()),
        ("seconds_per_op", seconds_per_op.into()),
    ])
}

fn main() {
    let gpu = GpuSpec::l20();
    let cost = calibrate(&gpu);
    let model = ModelConfig::qwen3b();
    let mut micro: Vec<Json> = Vec::new();
    let mut t = Table::new("L3 hot-path microbenchmarks", &["path", "per op", "note"]);

    // 1. Cost-model query (one phase prediction).
    let pre = model.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
    let dec = model.decode_ops(32, 32.0 * 1500.0);
    let per = time_it(200_000, || {
        std::hint::black_box(cost.prefill(std::hint::black_box(&pre), 0.6));
    });
    t.row(&["cost model: prefill query".into(), fmt_ns(per), "Eq. 5+8".into()]);
    micro.push(micro_row("costmodel_prefill_query", per));
    let per = time_it(200_000, || {
        std::hint::black_box(cost.decode(std::hint::black_box(&dec), 0.4, None));
    });
    t.row(&["cost model: decode query".into(), fmt_ns(per), "Eq. 6+9".into()]);
    micro.push(micro_row("costmodel_decode_query", per));

    // 2. Full partition decision (Algorithm 1).
    let mut ctl = PartitionController::new(PartitionConfig::default());
    let st = BatchState { prefill_ops: &pre, decode_ops: &dec, kv_usage: 0.5 };
    let per = time_it(20_000, || {
        std::hint::black_box(ctl.decide(&cost, &st));
    });
    t.row(&[
        "partition decision (Alg. 1)".into(),
        fmt_ns(per),
        "target ≪ 1 ms/batch".into(),
    ]);
    micro.push(micro_row("partition_decision", per));

    // 3. SPF scheduling over a deep queue.
    let mut rng = Rng::new(1);
    let queue: Vec<PrefillItem> = (0..10_000)
        .map(|id| PrefillItem {
            id,
            prompt_len: rng.range_usize(16, 10_000),
            prefilled: 0,
            arrival: rng.range_f64(0.0, 100.0),
        })
        .collect();
    let per = time_it(500, || {
        std::hint::black_box(spf_batch(std::hint::black_box(&queue), 50.0, 2048, 15.0));
    });
    t.row(&["SPF batch over 10k queue".into(), fmt_ns(per), "Alg. 2".into()]);
    micro.push(micro_row("spf_batch_10k", per));

    // 4. Simulator kernel throughput (events/sec).
    let ops = model.decode_ops(16, 16.0 * 1000.0);
    let n_kernels = 20_000;
    let t0 = Instant::now();
    let mut sim = Sim::new(gpu, 2);
    sim.set_partition(0, 0.5);
    sim.set_partition(1, 0.5);
    let mut done = 0usize;
    let mut tag = 0;
    while done < n_kernels {
        for s in 0..2 {
            if !sim.busy(s) {
                tag += 1;
                sim.submit(s, &ops, tag);
            }
        }
        let t_next = sim.peek_next_completion().unwrap();
        done += sim.advance_to(t_next + 1e-12).len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let per_kernel = wall / (n_kernels as f64 * ops.len() as f64);
    t.row(&[
        "gpusim kernel event".into(),
        fmt_ns(per_kernel),
        format!("{:.1}M kernels/s", 1e-6 / per_kernel),
    ]);
    micro.push(micro_row("gpusim_kernel_event", per_kernel));

    // 5. End-to-end experiment turnaround (sim seconds per wall second).
    let exp = Experiment::new(model, nexus::workload::Dataset::ShareGpt, 60, 4.0);
    let t0 = Instant::now();
    let m = exp.run(EngineKind::Nexus);
    let wall = t0.elapsed().as_secs_f64();
    t.row(&[
        "Nexus engine end-to-end".into(),
        format!("{:.2}s wall", wall),
        format!("{:.0}x realtime ({:.1}s sim)", m.makespan / wall, m.makespan),
    ]);
    micro.push(micro_row("nexus_engine_end_to_end_wall_s", wall));

    // 6. Shard-rebalance decision: the coordinator runs this every balance
    //    interval on the rendezvous path, so it must stay far below the
    //    cost of a round. 256 replicas on 8 shards, load piled on shard 0.
    let owner: Vec<usize> = (0..256).map(|i| i % 8).collect();
    let cands: Vec<(usize, u64)> = (0..256)
        .map(|i| (i, if i % 8 == 0 { 5_000 } else { 50 + i as u64 }))
        .collect();
    let mut base_loads = vec![0u64; 8];
    for &(id, l) in &cands {
        base_loads[owner[id]] += l;
    }
    let mut loads = vec![0u64; 8];
    let mut moves = Vec::new();
    let per = time_it(50_000, || {
        loads.copy_from_slice(&base_loads);
        plan_rebalance(&mut loads, &cands, &owner, 1.5, &[], &mut moves);
        std::hint::black_box(&moves);
    });
    t.row(&[
        "shard rebalance (256 reps / 8 shards)".into(),
        fmt_ns(per),
        format!("{} moves", moves.len()),
    ]);
    micro.push(micro_row("shard_rebalance_decision", per));

    t.print();

    // 7. Fleet-scale macro-benchmark: event-queue loop vs. reference loop.
    let mut ft = Table::new(
        "fleet macro-benchmark (bursty ShareGPT, Nexus engine, JSQ)",
        &["replicas", "events", "ref ev/s", "opt ev/s", "speedup"],
    );
    let mut fleet_rows: Vec<Json> = Vec::new();
    for &(replicas, n_req, rate) in &[(16usize, 1200usize, 28.0f64), (64, 2400, 110.0)] {
        let bursty = nexus::workload::BurstyCfg {
            base_rate: rate,
            ..nexus::workload::BurstyCfg::default()
        };
        let trace = nexus::workload::generate_bursty(
            nexus::workload::Dataset::ShareGpt,
            n_req,
            &bursty,
            97,
        );
        let cc = ClusterCfg::new(
            EngineKind::Nexus,
            EngineCfg::new(model, 5),
            replicas,
            RoutingPolicy::JoinShortestQueue,
        );
        eprintln!("  fleet x{replicas}: reference loop ({n_req} requests)...");
        let t0 = Instant::now();
        let m_ref = Cluster::new(cc.clone()).run_reference(&trace);
        let wall_ref = t0.elapsed().as_secs_f64();
        eprintln!("  fleet x{replicas}: optimized loop...");
        let t0 = Instant::now();
        let m_opt = Cluster::new(cc).run(&trace);
        let wall_opt = t0.elapsed().as_secs_f64();
        let dev = m_opt.fleet.deviation(&m_ref.fleet);
        assert!(
            matches!(dev, Some(d) if d <= 1e-9),
            "optimized loop diverged from reference in the macro-benchmark \
             (deviation {dev:?})"
        );
        let eps_ref = m_ref.events as f64 / wall_ref.max(1e-12);
        let eps_opt = m_opt.events as f64 / wall_opt.max(1e-12);
        ft.row(&[
            format!("{replicas}"),
            format!("{}", m_opt.events),
            format!("{:.0}", eps_ref),
            format!("{:.0}", eps_opt),
            format!("{:.2}x", eps_opt / eps_ref),
        ]);
        fleet_rows.push(Json::obj(vec![
            ("replicas", replicas.into()),
            ("threads", 1usize.into()),
            ("engine", "nexus".into()),
            ("policy", "jsq".into()),
            ("dataset", "sharegpt-bursty".into()),
            ("requests", n_req.into()),
            ("completed", m_opt.fleet.records.len().into()),
            ("events_reference", m_ref.events.into()),
            ("events_optimized", m_opt.events.into()),
            ("wall_s_reference", wall_ref.into()),
            ("wall_s_optimized", wall_opt.into()),
            ("events_per_sec_reference", eps_ref.into()),
            ("events_per_sec_optimized", eps_opt.into()),
            ("speedup", (eps_opt / eps_ref).into()),
        ]));
    }
    ft.print();

    // 8. Sharded-loop scaling sweep (§Perf, schema v2): replicas × worker
    //    threads. Every thread count is digest-checked against one thread,
    //    and the materialized rows additionally against the sequential
    //    loop, so every timing below is for *identical* served output.
    //    The 1024-replica row streams arrivals (no materialized trace).
    let mut pt = Table::new(
        "parallel fleet scaling (bursty ShareGPT, Nexus engine, JSQ)",
        &["replicas", "threads", "wall", "ev/s", "speedup"],
    );
    let mut scaling_rows: Vec<Json> = Vec::new();
    for &(replicas, n_req, rate, streamed) in &[
        (64usize, 2400usize, 110.0f64, false),
        (256, 4800, 440.0, false),
        (1024, 9600, 1760.0, true),
    ] {
        let bursty = nexus::workload::BurstyCfg {
            base_rate: rate,
            ..nexus::workload::BurstyCfg::default()
        };
        let trace = if streamed {
            Vec::new()
        } else {
            nexus::workload::generate_bursty(
                nexus::workload::Dataset::ShareGpt,
                n_req,
                &bursty,
                97,
            )
        };
        let cc = ClusterCfg::new(
            EngineKind::Nexus,
            EngineCfg::new(model, 5),
            replicas,
            RoutingPolicy::JoinShortestQueue,
        );
        // Sequential anchor for the materialized rows: the digest every
        // thread count must reproduce, and the speedup denominator.
        let mut anchor_digest = None;
        let mut anchor_wall = 0.0f64;
        let mut anchor_events = 0usize;
        if !streamed {
            eprintln!("  scale x{replicas}: sequential loop ({n_req} requests)...");
            let t0 = Instant::now();
            let m = Cluster::new(cc.clone()).run(&trace);
            anchor_wall = t0.elapsed().as_secs_f64();
            anchor_events = m.events;
            anchor_digest = Some(m.digest());
        }
        for &threads in &[1usize, 4, 8] {
            eprintln!("  scale x{replicas}: {threads} thread(s)...");
            let t0 = Instant::now();
            let m = if streamed {
                let reqs = nexus::workload::generate_bursty_iter(
                    nexus::workload::Dataset::ShareGpt,
                    n_req,
                    &bursty,
                    97,
                );
                Cluster::new(cc.clone()).run_parallel_stream(reqs, None, threads, 0.0)
            } else {
                Cluster::new(cc.clone()).run_parallel(&trace, threads, 0.0)
            };
            let wall = t0.elapsed().as_secs_f64();
            match anchor_digest {
                // Materialized rows anchor on the sequential loop; the
                // streamed row anchors on its own 1-thread run.
                Some(d) => assert_eq!(
                    d,
                    m.digest(),
                    "x{replicas} @ {threads} threads: parallel loop diverged"
                ),
                None => {
                    anchor_wall = wall;
                    anchor_events = m.events;
                    anchor_digest = Some(m.digest());
                }
            }
            // Throughput is normalized to the anchor's event count: every
            // run served identical output, so "events/sec" compares like
            // with like even though the sharded loop's own counter counts
            // rounds + steps rather than loop events.
            let eps = anchor_events as f64 / wall.max(1e-12);
            let speedup = anchor_wall / wall.max(1e-12);
            pt.row(&[
                format!("{replicas}{}", if streamed { " (streamed)" } else { "" }),
                format!("{threads}"),
                format!("{:.2}s", wall),
                format!("{:.0}", eps),
                format!("{:.2}x", speedup),
            ]);
            scaling_rows.push(Json::obj(vec![
                ("replicas", replicas.into()),
                ("threads", threads.into()),
                ("engine", "nexus".into()),
                ("policy", "jsq".into()),
                ("dataset", "sharegpt-bursty".into()),
                ("requests", n_req.into()),
                ("completed", m.fleet.records.len().into()),
                ("streamed", streamed.into()),
                ("events", m.events.into()),
                ("wall_s", wall.into()),
                ("events_per_sec", eps.into()),
                ("speedup_vs_sequential", speedup.into()),
            ]));
        }
    }
    pt.print();

    // 9. Skewed-fleet stealing sweep: session-affinity traffic with 90 % of
    //    requests on 8 hot sessions, plus autoscale churn. A warmup wave of
    //    64 simultaneous t=0 arrivals (sessions 0..63) pins session k to
    //    replica k via the JSQ-fallback cascade, so the hot sessions
    //    {0, 8, .., 56} land on replicas ≡ 0 (mod 8) — i.e. all on shard 0
    //    under the static `id % threads` partition at 4 and 8 threads. This
    //    is the adversarial case stealing exists for; every run is digest-
    //    checked against the sequential loop, so the stealing-vs-static
    //    delta is timing for *identical* served output.
    let mut st_tab = Table::new(
        "skewed-fleet stealing sweep (90% hot affinity traffic, autoscaled)",
        &["replicas", "threads", "steal", "wall", "ev/s", "vs static", "moves"],
    );
    let hot = |i: usize| 8 * (i % 8); // sessions 0, 8, .., 56
    let steal_cfg = StealCfg { threshold: 1.5, interval: 1.0 };
    for &(replicas, n_req, rate) in &[
        (64usize, 2000usize, 90.0f64),
        (256, 4000, 360.0),
        (1024, 8000, 1440.0),
    ] {
        let bursty = nexus::workload::BurstyCfg {
            base_rate: rate,
            ..nexus::workload::BurstyCfg::default()
        };
        let base = nexus::workload::generate_bursty(
            nexus::workload::Dataset::ShareGpt,
            n_req,
            &bursty,
            97,
        );
        let mut trace = Vec::with_capacity(n_req + 64);
        for k in 0..64usize {
            trace.push(nexus::workload::Request {
                id: k,
                arrival: 0.0,
                prompt_len: 64,
                output_len: 4,
                tenant: 0,
                prefix: 0,
                shared_len: 0,
            });
        }
        for (i, r) in base.iter().enumerate() {
            // 90 % hot; cold sessions get offsets 1..7 (never ≡ 0 mod 8).
            let session = if i % 10 < 9 { hot(i) } else { 8 * (i % 8) + 1 + i % 7 };
            trace.push(nexus::workload::Request {
                id: (i + 1) * 64 + session,
                ..*r
            });
        }
        let mut cc = ClusterCfg::new(
            EngineKind::Nexus,
            EngineCfg::new(model, 5),
            replicas,
            RoutingPolicy::SessionAffinity,
        );
        cc.autoscale = Some(nexus::cluster::AutoscalerCfg {
            min_replicas: replicas / 2,
            max_replicas: replicas + replicas / 4,
            interval: 2.0,
            cooldown: 4.0,
            ..nexus::cluster::AutoscalerCfg::default()
        });
        eprintln!("  skew x{replicas}: sequential loop ({} requests)...", trace.len());
        let t0 = Instant::now();
        let m = Cluster::new(cc.clone()).run(&trace);
        let anchor_wall = t0.elapsed().as_secs_f64();
        let anchor_events = m.events;
        let anchor_digest = m.digest();
        for &threads in &[1usize, 4, 8] {
            let mut static_wall = 0.0f64;
            for steal in [None, Some(steal_cfg)] {
                let label = if steal.is_some() { "on" } else { "off" };
                eprintln!("  skew x{replicas}: {threads} thread(s), stealing {label}...");
                let t0 = Instant::now();
                let m = Cluster::new(cc.clone()).run_parallel_cfg(
                    &trace,
                    ParallelCfg { threads, window: 0.0, steal },
                );
                let wall = t0.elapsed().as_secs_f64();
                assert_eq!(
                    anchor_digest,
                    m.digest(),
                    "skewed x{replicas} @ {threads} threads (stealing {label}): \
                     parallel loop diverged"
                );
                if steal.is_none() {
                    static_wall = wall;
                }
                let eps = anchor_events as f64 / wall.max(1e-12);
                let vs_static = static_wall / wall.max(1e-12);
                let (sh_min, sh_max) = match (m.shard_steps.iter().min(), m.shard_steps.iter().max())
                {
                    (Some(&lo), Some(&hi)) => (lo, hi),
                    _ => (0, 0),
                };
                st_tab.row(&[
                    format!("{replicas}"),
                    format!("{threads}"),
                    label.into(),
                    format!("{:.2}s", wall),
                    format!("{:.0}", eps),
                    format!("{:.2}x", vs_static),
                    format!("{}", m.rebalances),
                ]);
                scaling_rows.push(Json::obj(vec![
                    ("replicas", replicas.into()),
                    ("threads", threads.into()),
                    ("engine", "nexus".into()),
                    ("policy", "affinity".into()),
                    ("dataset", "sharegpt-bursty-skewed".into()),
                    ("requests", trace.len().into()),
                    ("completed", m.fleet.records.len().into()),
                    ("streamed", false.into()),
                    ("skewed", true.into()),
                    ("stealing", steal.is_some().into()),
                    ("rebalances", m.rebalances.into()),
                    ("shard_steps_min", (sh_min as usize).into()),
                    ("shard_steps_max", (sh_max as usize).into()),
                    ("events", m.events.into()),
                    ("wall_s", wall.into()),
                    ("events_per_sec", eps.into()),
                    ("speedup_vs_sequential", (anchor_wall / wall.max(1e-12)).into()),
                    ("speedup_vs_static", vs_static.into()),
                ]));
            }
        }
    }
    st_tab.print();

    // 10. Fleet prefix-cache sweep (§Perf, schema v3): routing policy × tier
    //     fabric, on a chat-heavy multi-turn trace (95 % warm turns sharing
    //     ~3/4 of the prompt across 12 sessions) and on untagged single-turn
    //     traffic. The chat rows carry the PR-10 headline — prefix-aware
    //     routing plus the fleet tier vs session affinity at equal offered
    //     load must cut mean TTFT ≥ 1.5× — and the single-turn prefix row is
    //     digest-checked against JSQ (cold prefix-aware degenerates exactly).
    let mut px = Table::new(
        "fleet prefix-cache sweep (Nexus engine, 4 replicas)",
        &["workload", "policy", "tier", "wall", "mean TTFT", "hit rate", "saved"],
    );
    let mut prefix_rows: Vec<Json> = Vec::new();
    let chat_pcfg = nexus::workload::PrefixCfg {
        sessions: 12,
        hit_prob: 0.95,
        mean_frac: 0.75,
        seed: 0x51C2,
    };
    let chat = nexus::workload::generate_with_prefixes(
        nexus::workload::Dataset::ShareGpt,
        300,
        10.0,
        23,
        &chat_pcfg,
    );
    let single = nexus::workload::generate(nexus::workload::Dataset::Arxiv, 120, 3.0, 23);
    for (workload, trace) in [("chat-multiturn", &chat), ("single-turn", &single)] {
        let mut affinity_ttft = 0.0f64;
        let mut jsq_digest = None;
        for (policy_name, policy, cache) in [
            ("affinity", RoutingPolicy::SessionAffinity, None),
            ("jsq", RoutingPolicy::JoinShortestQueue, None),
            (
                "prefix",
                RoutingPolicy::PrefixAware,
                Some(Some(nexus::cluster::TierCfg::rdma())),
            ),
            ("prefix-no-tier", RoutingPolicy::PrefixAware, Some(None)),
        ] {
            let mut cc = ClusterCfg::new(EngineKind::Nexus, EngineCfg::new(model, 5), 4, policy);
            if let Some(tier) = cache {
                cc.prefix = Some(nexus::cluster::PrefixCacheCfg {
                    tier,
                    ..nexus::cluster::PrefixCacheCfg::default()
                });
            }
            eprintln!("  prefix sweep [{workload}]: {policy_name}...");
            let t0 = Instant::now();
            let m = Cluster::new(cc).run(trace);
            let wall = t0.elapsed().as_secs_f64();
            let s = m.summary();
            if policy_name == "affinity" {
                affinity_ttft = s.mean_ttft;
            }
            if policy_name == "jsq" {
                jsq_digest = Some(m.digest());
            }
            if workload == "single-turn" && policy_name == "prefix" {
                assert_eq!(
                    jsq_digest,
                    Some(m.digest()),
                    "cold prefix-aware must serve exactly as JSQ"
                );
            }
            let speedup = affinity_ttft / s.mean_ttft.max(1e-12);
            if workload == "chat-multiturn" && policy_name == "prefix" {
                assert!(
                    speedup >= 1.5,
                    "prefix-aware + tier must cut chat TTFT ≥ 1.5x vs affinity \
                     (got {speedup:.2}x: affinity {affinity_ttft:.4}s vs {:.4}s)",
                    s.mean_ttft
                );
            }
            let tier_label = match cache {
                None => "-",
                Some(Some(_)) => "rdma",
                Some(None) => "none",
            };
            px.row(&[
                workload.into(),
                policy_name.into(),
                tier_label.into(),
                format!("{:.2}s", wall),
                format!("{:.4}s", s.mean_ttft),
                if m.prefix.lookups > 0 {
                    format!("{:.1}%", 100.0 * m.prefix.hit_rate())
                } else {
                    "-".into()
                },
                format!("{}", m.prefix.tokens_saved),
            ]);
            prefix_rows.push(Json::obj(vec![
                ("workload", workload.into()),
                ("policy", policy_name.into()),
                ("tier", tier_label.into()),
                ("replicas", 4usize.into()),
                ("requests", trace.len().into()),
                ("completed", m.fleet.records.len().into()),
                ("wall_s", wall.into()),
                ("mean_ttft_s", s.mean_ttft.into()),
                ("ttft_speedup_vs_affinity", speedup.into()),
                ("prefix_lookups", (m.prefix.lookups as usize).into()),
                ("prefix_hit_rate", m.prefix.hit_rate().into()),
                ("prefix_tokens_saved", (m.prefix.tokens_saved as usize).into()),
                ("prefix_evictions", (m.prefix.evictions as usize).into()),
            ]));
        }
    }
    px.print();

    // Machine-readable dump for the perf trajectory (ROADMAP §Perf).
    let out = Json::obj(vec![
        ("bench", "perf_hotpath".into()),
        ("schema_version", 3usize.into()),
        ("status", "measured".into()),
        ("fleet", Json::Arr(fleet_rows)),
        ("scaling", Json::Arr(scaling_rows)),
        ("prefix", Json::Arr(prefix_rows)),
        ("micro", Json::Arr(micro)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_hotpath.json");
    std::fs::write(&path, format!("{out}\n")).expect("write BENCH_hotpath.json");
    println!("wrote {}", path.display());
}

fn fmt_ns(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.0} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else {
        format!("{:.2} ms", secs * 1e3)
    }
}
