//! §Perf — hot-path microbenchmarks for the L3 coordinator. These anchor
//! the EXPERIMENTS.md §Perf iteration log: the partition decision must be
//! ≪ 1 ms (it runs per batch inside the serving loop), the simulator event
//! loop bounds experiment turnaround, and the schedulers must stay
//! negligible (Fig. 12's "scheduling overhead" row).
//!
//! `cargo bench --bench perf_hotpath`

use nexus::coordinator::Experiment;
use nexus::costmodel::calibrate;
use nexus::engine::EngineKind;
use nexus::gpusim::{GpuSpec, Sim};
use nexus::model::ModelConfig;
use nexus::partition::{BatchState, PartitionConfig, PartitionController};
use nexus::sched::{spf_batch, PrefillItem};
use nexus::util::fmt::Table;
use nexus::util::rng::Rng;
use std::time::Instant;

fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let gpu = GpuSpec::l20();
    let cost = calibrate(&gpu);
    let model = ModelConfig::qwen3b();
    let mut t = Table::new("L3 hot-path microbenchmarks", &["path", "per op", "note"]);

    // 1. Cost-model query (one phase prediction).
    let pre = model.prefill_ops(512, 512.0 * 4000.0, 4000.0, 0);
    let dec = model.decode_ops(32, 32.0 * 1500.0);
    let per = time_it(200_000, || {
        std::hint::black_box(cost.prefill(std::hint::black_box(&pre), 0.6));
    });
    t.row(&["cost model: prefill query".into(), fmt_ns(per), "Eq. 5+8".into()]);
    let per = time_it(200_000, || {
        std::hint::black_box(cost.decode(std::hint::black_box(&dec), 0.4, None));
    });
    t.row(&["cost model: decode query".into(), fmt_ns(per), "Eq. 6+9".into()]);

    // 2. Full partition decision (Algorithm 1).
    let mut ctl = PartitionController::new(PartitionConfig::default());
    let st = BatchState { prefill_ops: &pre, decode_ops: &dec, kv_usage: 0.5 };
    let per = time_it(20_000, || {
        std::hint::black_box(ctl.decide(&cost, &st));
    });
    t.row(&[
        "partition decision (Alg. 1)".into(),
        fmt_ns(per),
        "target ≪ 1 ms/batch".into(),
    ]);

    // 3. SPF scheduling over a deep queue.
    let mut rng = Rng::new(1);
    let queue: Vec<PrefillItem> = (0..10_000)
        .map(|id| PrefillItem {
            id,
            prompt_len: rng.range_usize(16, 10_000),
            prefilled: 0,
            arrival: rng.range_f64(0.0, 100.0),
        })
        .collect();
    let per = time_it(500, || {
        std::hint::black_box(spf_batch(std::hint::black_box(&queue), 50.0, 2048, 15.0));
    });
    t.row(&["SPF batch over 10k queue".into(), fmt_ns(per), "Alg. 2".into()]);

    // 4. Simulator kernel throughput (events/sec).
    let ops = model.decode_ops(16, 16.0 * 1000.0);
    let n_kernels = 20_000;
    let t0 = Instant::now();
    let mut sim = Sim::new(gpu, 2);
    sim.set_partition(0, 0.5);
    sim.set_partition(1, 0.5);
    let mut done = 0usize;
    let mut tag = 0;
    while done < n_kernels {
        for s in 0..2 {
            if !sim.busy(s) {
                tag += 1;
                sim.submit(s, &ops, tag);
            }
        }
        let t_next = sim.peek_next_completion().unwrap();
        done += sim.advance_to(t_next + 1e-12).len();
    }
    let wall = t0.elapsed().as_secs_f64();
    let per_kernel = wall / (n_kernels as f64 * ops.len() as f64);
    t.row(&[
        "gpusim kernel event".into(),
        fmt_ns(per_kernel),
        format!("{:.1}M kernels/s", 1e-6 / per_kernel),
    ]);

    // 5. End-to-end experiment turnaround (sim seconds per wall second).
    let exp = Experiment::new(model, nexus::workload::Dataset::ShareGpt, 60, 4.0);
    let t0 = Instant::now();
    let m = exp.run(EngineKind::Nexus);
    let wall = t0.elapsed().as_secs_f64();
    t.row(&[
        "Nexus engine end-to-end".into(),
        format!("{:.2}s wall", wall),
        format!("{:.0}x realtime ({:.1}s sim)", m.makespan / wall, m.makespan),
    ]);

    t.print();
}

fn fmt_ns(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.0} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} µs", secs * 1e6)
    } else {
        format!("{:.2} ms", secs * 1e3)
    }
}
