# Top-level targets for the Nexus reproduction.
#
#   make ci         — build + tests + fmt + clippy on the rust crate
#   make test       — tier-1 verify (cargo build --release && cargo test -q)
#   make artifacts  — AOT-lower the JAX/Pallas tiny model to PJRT artifacts
#                     (needed only by the `pjrt` feature / `nexus live`)

.PHONY: ci test artifacts

ci:
	./ci.sh

test:
	cd rust && cargo build --release && cargo test -q

artifacts:
	cd python && python3 compile/aot.py --out ../rust/artifacts
