# Top-level targets for the Nexus reproduction.
#
#   make ci         — build + tests + bench compile + fmt + clippy on the rust crate
#   make test       — tier-1 verify (cargo build --release && cargo test -q)
#   make bench-json — regenerate BENCH_hotpath.json (fleet macro-benchmark +
#                     hot-path microbenchmarks; schema in ROADMAP §Perf)
#   make artifacts  — AOT-lower the JAX/Pallas tiny model to PJRT artifacts
#                     (needed only by the `pjrt` feature / `nexus live`)

.PHONY: ci test bench-json artifacts

ci:
	./ci.sh

test:
	cd rust && cargo build --release && cargo test -q

bench-json:
	cd rust && cargo bench --bench perf_hotpath

artifacts:
	cd python && python3 compile/aot.py --out ../rust/artifacts
